"""Static plan vs online refit under injected t0/BW drift.

The ROADMAP's staleness scenario, made measurable: both engines are planned
for the SAME baseline transfer behaviour, then the per-descriptor cost is
drifted (fixed overhead up, bandwidth down — the signature of a host that
picked up load, the paper's 'the driver path is the bottleneck' regime).
The static :class:`~repro.core.channels.ChannelGroup` keeps flying its
now-stale block size and pays the inflated per-chunk overhead dozens of
times per payload; the :class:`~repro.core.adaptive.AdaptiveChannelGroup`
re-fits t0/BW from its rolling chunk samples, re-plans (bigger blocks,
fewer chunks, channel count re-derived), and swaps the plan at a drained
ring. The headline row is ``recovery_ratio``: stale-static us/B over
online-refit us/B in the post-drift steady state (>= 1.3 expected).

Drift is injected through ``ChannelGroup(engine_factory=...)``: a
:class:`TransferEngine` subclass whose ``_one`` sleeps
``t0 + nbytes/BW`` per chunk on top of the real copy — the measured path
stays real, only the simulated link condition changes.

Results merge into ``BENCH_transfer.json`` under ``"adaptive_drift"``.
``--quick`` shrinks payloads/iters for the CI smoke run (no JSON rewrite).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.adaptive import AdaptiveChannelGroup, AdaptiveConfig
from repro.core.channels import ChannelGroup, plan_channels
from repro.core.cost_model import TransferCostModel
from repro.core.transfer import TransferEngine, TransferPolicy

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"

# (t0_s, bw_Bps). Both t0 points sit above time.sleep's ~1 ms granularity
# floor so the injected overhead is actually realized; the drifted point is
# the paper's regime where the driver path (not the wire) bottlenecks, so a
# stale small block size pays the inflated t0 once per chunk.
BASELINE = (1e-3, 1e9)     # healthy host: ~1 MB optimal blocks
DRIFTED = (10e-3, 2e9)     # loaded host: 10x overhead, optimal = whole payload
QUICK_SCALE = 1            # payload sizes already cheap; quick trims iters


class DriftProfile:
    """Mutable synthetic link condition shared by every injected engine."""

    def __init__(self, t0_s: float, bw_Bps: float):
        self.t0_s = t0_s
        self.bw_Bps = bw_Bps

    def set(self, t0_s: float, bw_Bps: float) -> None:
        self.t0_s = t0_s
        self.bw_Bps = bw_Bps


def drifting_engine_factory(profile: DriftProfile):
    """Engine class whose every chunk pays the profile's t0 + n/BW.

    A real DMA channel moves one descriptor at a time, so per-chunk
    overhead cannot be hidden by sleeping on N completion workers at once:
    chunks serialize on a per-engine lock. The lock wait sits OUTSIDE the
    timed region (``_one_timed``) — queueing delay is not part of a
    descriptor's service time, and folding it into the chunk samples would
    poison the online fit with load-dependent noise. Striping across
    engines still parallelizes — that is the multi-channel lesson the
    planner is allowed to exploit."""
    import threading

    class DriftingEngine(TransferEngine):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self._drift_lock = threading.Lock()

        def _one_timed(self, payload, direction, out=None):
            with self._drift_lock:  # serialize; wait excluded from sample
                return super()._one_timed(payload, direction, out)

        def _one(self, payload, direction, out=None):
            if direction == "tx":
                nbytes = int(np.asarray(payload).nbytes)
            else:
                nbytes = int(payload.size) * payload.dtype.itemsize
            time.sleep(profile.t0_s + nbytes / profile.bw_Bps)
            return super()._one(payload, direction, out)

    return DriftingEngine


def measure_model(factory, sizes=(16 << 10, 256 << 10, 2 << 20),
                  repeats: int = 3) -> TransferCostModel:
    """Fit the baseline model the PLANNER sees, by measuring single-chunk
    transfers through an injected engine (so it includes the synthetic
    link, exactly like construction-time calibration would). Warm up
    first — the first device_put pays one-time dispatch/alloc costs that
    would masquerade as a ~ms fixed overhead and poison the fit."""
    eng = factory(TransferPolicy.user_level_polling())
    for _ in range(2):
        eng.tx(np.empty(sizes[0], np.uint8))
    ns, ts = [], []
    for n in sizes:
        x = np.empty(n, np.uint8)
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            eng.tx(x)
            best = min(best, time.perf_counter() - t0)
        ts.append(best)
        ns.append(n)
    eng.close()
    return TransferCostModel.fit(np.asarray(ns, np.float64),
                                 np.asarray(ts, np.float64))


def _phase(engine, payloads, iters: int, *, adapt: bool) -> float:
    """Transfer the payload mix ``iters`` times; returns the MEDIAN
    per-iteration us/B (one scheduler hiccup must not swing the phase)."""
    per_iter = []
    bytes_per_iter = sum(x.nbytes for x in payloads)
    for _ in range(iters):
        t_iter = 0.0
        for x in payloads:
            t0 = time.perf_counter()
            engine.tx(x)
            t_iter += time.perf_counter() - t0
            if adapt:
                engine.maybe_adapt()
        per_iter.append(t_iter * 1e6 / max(bytes_per_iter, 1))
    return sorted(per_iter)[len(per_iter) // 2]


def run(quick: bool = False) -> list[dict]:
    scale = QUICK_SCALE if quick else 1
    sizes = [(2 << 20) // scale, (4 << 20) // scale, (8 << 20) // scale]
    rng = np.random.default_rng(0)
    payloads = [rng.integers(0, 255, n, dtype=np.uint8) for n in sizes]
    pre_iters = 2 if quick else 3
    settle_iters = 4 if quick else 8   # post-drift iters the refit may use
    post_iters = 2 if quick else 5    # post-drift steady state (measured)

    profile = DriftProfile(*BASELINE)
    factory = drifting_engine_factory(profile)
    model0 = measure_model(factory)
    # max_channels=1: this benchmark isolates the paper's packet-length
    # lesson (block sizing under a drifted t0/BW). Striping is measured by
    # multichannel_sweep; letting the planner add channels here just
    # oversubscribes the 2-core CI host and noises the comparison.
    plan0 = plan_channels(max(sizes), model=model0, max_channels=1)

    static = ChannelGroup(plan0.policy, n_channels=plan0.n_channels,
                          engine_factory=factory)
    online = AdaptiveChannelGroup(
        max(sizes), model=model0, engine_factory=factory,
        cfg=AdaptiveConfig(refit_every=2, hysteresis=2.0, min_samples=10,
                           ewma_halflife=16, max_channels=1,
                           sample_ttl_s=1.0))

    rows: list[dict] = [{
        "bench": "adaptive_drift", "variant": "baseline_plan",
        "baseline_t0_us": BASELINE[0] * 1e6,
        "baseline_gbps": BASELINE[1] / 1e9,
        "drifted_t0_us": DRIFTED[0] * 1e6,
        "drifted_gbps": DRIFTED[1] / 1e9,
        **plan0.row(),
    }]

    # -- phase 1: both fly the baseline-fitted plan on the healthy link ----
    us_static_pre = _phase(static, payloads, pre_iters, adapt=False)
    us_online_pre = _phase(online, payloads, pre_iters, adapt=True)

    # -- drift: the link condition changes under both engines --------------
    profile.set(*DRIFTED)
    _phase(static, payloads, settle_iters, adapt=False)   # same cost, no gain
    _phase(online, payloads, settle_iters, adapt=True)    # refit + swap here

    # -- phase 2: post-drift steady state ----------------------------------
    us_static_post = _phase(static, payloads, post_iters, adapt=False)
    us_online_post = _phase(online, payloads, post_iters, adapt=True)

    adapt_row = online.adapt_summary()
    for variant, pre, post in (("static", us_static_pre, us_static_post),
                               ("online-refit", us_online_pre,
                                us_online_post)):
        rows.append({
            "bench": "adaptive_drift", "variant": variant,
            "payload_bytes": sum(sizes),
            "pre_drift_us_per_byte": round(pre, 6),
            "post_drift_us_per_byte": round(post, 6),
        })
    rows.append({
        "bench": "adaptive_drift", "variant": "adaptation",
        "recovery_ratio": round(us_static_post / max(us_online_post, 1e-12),
                                3),
        **adapt_row,
    })
    static.close()
    online.close()
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Fold the drift run into BENCH_transfer.json under ``adaptive_drift``."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    static = next(r for r in rows if r["variant"] == "static")
    online = next(r for r in rows if r["variant"] == "online-refit")
    adapt = next(r for r in rows if r["variant"] == "adaptation")
    doc["adaptive_drift"] = {
        "rows": rows,
        "static_post_drift_us_per_byte": static["post_drift_us_per_byte"],
        "online_post_drift_us_per_byte": online["post_drift_us_per_byte"],
        # the PR-3 headline: how much of the drift-induced loss the online
        # refit claws back vs the stale static plan (>= 1.3 expected)
        "recovery_ratio_static_over_online": adapt["recovery_ratio"],
        "plan_swaps": adapt["swaps"],
        "replans": adapt["replans"],
        "refits": adapt["refits"],
        "final_plan": adapt["plan"],
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small payloads/iters, no JSON rewrite (CI smoke)")
    args = ap.parse_args()
    bench_rows = run(quick=args.quick)
    for r in bench_rows:
        print(r)
    if not args.quick:
        doc = merge_bench_json(bench_rows)
        ad = doc["adaptive_drift"]
        print(f"wrote {BENCH_JSON}: post-drift static/online us/B recovery "
              f"ratio {ad['recovery_ratio_static_over_online']}")
