"""Scatter-gather vs staged-pack TX — the staging-copy cost, measured.

The PR-1 hot path pays a full host memcpy per layer set:
:meth:`~repro.core.transfer.StagedLayout.pack` copies every array into one
contiguous staging buffer before the descriptor is submitted. The
scatter-gather form (``tx_sg``) submits the SAME layer set as segment views
riding ONE ring slot — zero staging copy, but one descriptor-walk overhead
per segment (SNIPPETS.md Snippet 1's ISSUE_RD/WAIT_CPL loop). Which side
wins is a pure crossover in the fitted cost model:

    pack: total/copy_BW + t0 + total/BW       (memcpy, then one descriptor)
    SG:   t0 + K*seg_t0 + total/BW            (K segment walks, no memcpy)

so SG wins iff ``K * seg_t0 < total / copy_BW`` — few large segments ride
SG, many small arrays keep the pack. This benchmark sweeps segment count x
segment size over both regimes, records the measured crossover, and merges a
``"staging_copy"`` section into ``BENCH_transfer.json``; the few-large-
segments win is floored in ``scripts/check_bench.py``.

Pack timings use ``force=True``: the hot path this models carries fresh
bytes every frame (pipeline batches, activations), so the staging memcpy is
real — the unchanged-weights fast path that skips it is a different regime
and exactly the one where the SG decision does not matter.

``--quick`` shrinks the shapes and repeats for the CI smoke run (and does
not rewrite the JSON).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from repro.core.channels import calibrate_transfer
from repro.core.transfer import (
    StagedLayout,
    TransferEngine,
    TransferPolicy,
    choose_sg,
    host_copy_bw_Bps,
    sg_crossover_segments,
)

BENCH_JSON = pathlib.Path(
    __file__).resolve().parent.parent / "BENCH_transfer.json"

# (n_segments, bytes_per_segment): the two acceptance shapes plus a sweep
# spanning the crossover. FEW_LARGE matches the streaming_layers regime
# (a handful of >= MiB-scale per-layer params); MANY_SMALL is the
# pathological SG shape (hundreds of KiB-scale arrays, descriptor-walk
# overhead dominates).
FEW_LARGE = (4, 12 << 20)
MANY_SMALL = (512, 8 << 10)
SWEEP = [(2, 8 << 20), (8, 2 << 20), (32, 256 << 10), (128, 32 << 10)]
QUICK_FEW_LARGE = (4, 1 << 20)
QUICK_MANY_SMALL = (64, 8 << 10)
QUICK_SWEEP = [(2, 1 << 20), (32, 32 << 10)]


def _arrays(n: int, seg_bytes: int, rng: np.random.Generator) -> list:
    return [rng.standard_normal(seg_bytes // 4).astype(np.float32)
            for _ in range(n)]


def _measure(engine: TransferEngine, arrays: list,
             repeats: int) -> tuple[float, float]:
    """Best-of pack-vs-SG wall seconds for one layer set (interleaved
    trials, so allocator/page-cache drift hits both paths equally)."""
    lay = StagedLayout(arrays)
    segs = lay.sg_segments(arrays)
    # warmup both paths: prime the staging buffer, device allocator, rings
    jax.block_until_ready(lay.unpack(engine.tx(lay.pack(arrays,
                                                        force=True))))
    jax.block_until_ready(engine.tx_sg(segs).wait())
    pack_ts, sg_ts = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        dev = lay.unpack(engine.tx(lay.pack(arrays, force=True)))
        jax.block_until_ready(dev)
        pack_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        dev = engine.tx_sg(segs).wait()
        jax.block_until_ready(dev)
        sg_ts.append(time.perf_counter() - t0)
    lay.release()
    return min(pack_ts), min(sg_ts)


def _fit_seg_t0(rows: list[dict]) -> float:
    """Per-segment walk cost fitted from the measured SG walls over the
    sweep: t = t0 + K*seg_t0 + total/BW, least-squares over every
    (K, total, wall) point. This is the benchmark-side twin of the
    controller's live ``ingest_sg`` refit — the calibration sweep's fitted
    ``t0`` intercept is lost in noise on fast hosts, but the K-slope is
    directly observable once segment counts vary."""
    a = np.array([[1.0, r["n_segments"], r["total_bytes"]] for r in rows])
    b = np.array([r["sg_us_per_byte"] * 1e-6 * r["total_bytes"]
                  for r in rows])
    coef, *_ = np.linalg.lstsq(a, b, rcond=None)
    return float(max(coef[1], 1e-9))


def run(repeats: int = 5, quick: bool = False) -> list[dict]:
    repeats = 2 if quick else repeats
    few_large = QUICK_FEW_LARGE if quick else FEW_LARGE
    many_small = QUICK_MANY_SMALL if quick else MANY_SMALL
    sweep = QUICK_SWEEP if quick else SWEEP
    shapes = ([("few_large", *few_large), ("many_small", *many_small)]
              + [(f"sweep_{n}x{b >> 10}KiB", n, b) for n, b in sweep])

    model = calibrate_transfer()
    copy_bw = host_copy_bw_Bps()
    rng = np.random.default_rng(0)
    engine = TransferEngine(
        TransferPolicy.kernel_level_ring(4, block_bytes=1 << 20))
    rows = []
    try:
        for name, n, seg_bytes in shapes:
            arrays = _arrays(n, seg_bytes, rng)
            total = n * seg_bytes
            pack_s, sg_s = _measure(engine, arrays, repeats)
            rows.append({
                "bench": "sg_vs_pack", "shape": name,
                "n_segments": n, "seg_bytes": seg_bytes,
                "total_bytes": total,
                "pack_us_per_byte": round(pack_s * 1e6 / total, 6),
                "sg_us_per_byte": round(sg_s * 1e6 / total, 6),
                "pack_over_sg": round(pack_s / max(sg_s, 1e-12), 3),
            })
    finally:
        engine.close()
    # decisions use the seg_t0 refitted from THIS sweep's SG walls (the
    # live-controller crossover, not the calibration intercept)
    seg_t0 = _fit_seg_t0(rows)
    for r in rows:
        r["decision"] = ("sg" if choose_sg(
            [r["seg_bytes"]] * r["n_segments"], model,
            seg_t0_s=seg_t0, copy_bw_Bps=copy_bw) else "pack")
    rows.append({
        "bench": "sg_vs_pack", "shape": "calibration",
        "model_t0_us": round(model.t0_s * 1e6, 3),
        "model_bw_GBps": round(model.bw_Bps / 1e9, 3),
        "host_copy_bw_GBps": round(copy_bw / 1e9, 3),
        "seg_t0_us_fitted": round(seg_t0 * 1e6, 3),
        # fitted crossover at the few-large total: layer sets with FEWER
        # segments than this ride SG, more ride the pack
        "crossover_segments": round(sg_crossover_segments(
            few_large[0] * few_large[1], model,
            seg_t0_s=seg_t0, copy_bw_Bps=copy_bw), 1),
    })
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Fold the sweep into BENCH_transfer.json under ``"staging_copy"``."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    few = next(r for r in rows if r["shape"] == "few_large")
    small = next(r for r in rows if r["shape"] == "many_small")
    calib = next(r for r in rows if r["shape"] == "calibration")
    doc["staging_copy"] = {
        "rows": rows,
        "pack_us_per_byte_few_large": few["pack_us_per_byte"],
        "sg_us_per_byte_few_large": few["sg_us_per_byte"],
        # the acceptance headline: scatter-gather vs staged-pack TX us/B on
        # the few-large-segments shape (>1 = killing the staging copy won)
        "pack_over_sg_us_per_byte_few_large": round(
            few["pack_us_per_byte"]
            / max(few["sg_us_per_byte"], 1e-12), 3),
        # the cost-model decisions the hot path memoizes: SG for few large
        # segments, pack for many small arrays — automatically.
        "decision_few_large": few["decision"],
        "decision_many_small": small["decision"],
        "crossover_segments": calib["crossover_segments"],
        "host_copy_bw_GBps": calib["host_copy_bw_GBps"],
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes, no JSON rewrite (CI smoke)")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()
    bench_rows = run(repeats=args.repeats, quick=args.quick)
    for r in bench_rows:
        print(r)
    if not args.quick:
        doc = merge_bench_json(bench_rows)
        sc = doc["staging_copy"]
        print(f"wrote {BENCH_JSON}: pack/SG tx us/B ratio (few-large) "
              f"{sc['pack_over_sg_us_per_byte_few_large']}, decisions "
              f"few-large={sc['decision_few_large']} "
              f"many-small={sc['decision_many_small']}")
