"""Table I reproduction: RoShamBo CNN frame execution on the NullHop-style
executor — TX/RX us/byte + frame ms for the three driver modes
(unique mode, single buffer, exactly as the paper's table)."""

from __future__ import annotations

import jax
import numpy as np

from repro.accel.nullhop import NullHopExecutor
from repro.accel.roshambo import RoShamBoCNN
from repro.core.transfer import TransferPolicy

DRIVERS = [
    ("user-level polling", TransferPolicy.user_level_polling),
    ("user-level drv scheduled", TransferPolicy.user_level_scheduled),
    ("kernel-level drv", TransferPolicy.kernel_level),
]

# paper's Table I (us/byte, ms) for qualitative comparison
PAPER = {
    "user-level polling": (0.0054, 0.197, 6.31),
    "user-level drv scheduled": (0.0072, 0.335, 6.57),
    "kernel-level drv": (0.011, 0.294, 7.39),
}


def run(iters: int = 3) -> list[dict]:
    cnn = RoShamBoCNN()
    params = cnn.init(jax.random.PRNGKey(0))
    frame = np.random.default_rng(0).standard_normal(
        (1, 64, 64, 1)).astype(np.float32)
    rows = []
    for name, mk in DRIVERS:
        ex = NullHopExecutor(cnn, mk())
        ex.run_frame(params, frame)  # jit warmup
        best = None
        for _ in range(iters):
            res = ex.run_frame(params, frame)
            if best is None or res.timing.frame_s < best.timing.frame_s:
                best = res
        t = best.timing
        p_tx, p_rx, p_f = PAPER[name]
        rows.append({
            "bench": "roshambo_table", "driver": name,
            "tx_us_per_byte": round(t.tx_us_per_byte, 5),
            "rx_us_per_byte": round(t.rx_us_per_byte, 5),
            "frame_ms": round(t.frame_s * 1e3, 2),
            "paper_tx": p_tx, "paper_rx": p_rx, "paper_frame_ms": p_f,
            "mean_sparsity": round(float(np.mean(best.sparsity)), 3),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
