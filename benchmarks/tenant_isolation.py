"""Heavy-hitter isolation across 1000 tenants of ONE priority class.

The tentpole claim of the second arbitration tier: per-tenant byte-weighted
fair queuing *inside* a class means one tenant flooding megabyte
descriptors cannot make the other 999 tenants wait out its backlog. The
class tier alone (PR 5's WFQ between classes) cannot help here — every
tenant is BULK, so a single-tier runtime serves the flood FIFO and every
victim queues behind the whole backlog.

Synthetic population: 999 victim tenants drawing submissions from a
zipf(1.2) popularity curve (a few hot tenants, a long tail — the shape a
multi-tenant serving box actually sees) plus one flooding tenant that
keeps a deep backlog of 1 MiB descriptors queued at all times. Victims
submit 4 KiB descriptors one at a time and measure submit->completion
wall time. Four variants:

- ``noflood``         : two-tier runtime, victims only — the baseline p99.
- ``flood-single``    : ``TransferRuntime(tenant_fair=False)`` + flood —
                        tier 2 disabled, victims queue FIFO behind the
                        flood backlog (the ablation arm).
- ``flood-wfq``       : two-tier runtime + flood — per-tenant vtime makes
                        each 4 KiB victim descriptor win the next dispatch
                        slot over the flood's megabyte-charged flow.
- ``flood-cap-admit`` : flood-wfq plus a leaf cap on the flooder's flow
                        (the cap tree's per-tenant bucket) and an
                        :class:`AdmissionController` consulted before each
                        flood top-up — deferrals and sheds must both show
                        up in the ledgers.

Headline: ``isolation_ratio_wfq`` (flood-wfq victim p99 over noflood) is
the acceptance bar — scripts/check_bench.py fails the committed file when
it exceeds 1.5x, or when the single-tier ratio does not exceed the WFQ
ratio (tier 2 rotted into a no-op).

    PYTHONPATH=src python benchmarks/tenant_isolation.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time

import numpy as np

from repro.core.qos import AdmissionController, AdmissionPolicy, QosSpec
from repro.core.runtime import (
    ClassQos,
    PriorityClass,
    TransferRuntime,
    _pct,
)

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_transfer.json"

N_TENANTS = 1000          # 999 zipf victims + 1 flooder
ZIPF_A = 1.2
FLOOD_DEPTH = 32          # descriptors the flooder keeps queued
FLOOD_NBYTES = 1 << 20    # megabyte descriptors: WFQ charges by bytes...
FLOOD_SERVICE_S = 300e-6  # ...but each holds the worker only briefly
VICTIM_NBYTES = 4 << 10
VICTIM_SERVICE_S = 2e-3   # victim service time dominates its OWN latency
FLOOD_CAP_BPS = 64e6      # leaf cap for the cap-admit variant (~64 desc/s)
CLS = PriorityClass.BULK


def _victim_tenant(rng: np.random.Generator) -> str:
    """One zipf(1.2) draw folded onto the 999 victim ids."""
    return f"t{(int(rng.zipf(ZIPF_A)) - 1) % (N_TENANTS - 1) + 1}"


def _flood_loop(h, rt, stop: threading.Event, counters: dict,
                admission: AdmissionController | None) -> None:
    """Keep ``FLOOD_DEPTH`` flood descriptors queued; optionally ask the
    admission controller before each top-up burst (the serving-layer seam
    a real multi-tenant frontend would sit behind)."""
    spec = QosSpec(tenant="flood")
    # track the backlog with our own completion events, not
    # rt.tenant_depth: the single-tier ablation arm ignores tenant tags,
    # so the runtime-side depth reads 0 there and would unbound the flood.
    pending: list[threading.Event] = []
    while not stop.is_set():
        pending = [ev for ev in pending if not ev.is_set()]
        counters["depth"] = len(pending)
        if len(pending) >= FLOOD_DEPTH:
            time.sleep(FLOOD_SERVICE_S)
            continue
        if admission is not None:
            d = admission.decide("flood", cls=CLS)
            if not d.admitted:
                counters["sheds"] += 1
                time.sleep(d.retry_after_s or 1e-3)
                continue
        for _ in range(FLOOD_DEPTH - len(pending)):
            ev, _ = h.submit(lambda: time.sleep(FLOOD_SERVICE_S),
                             nbytes=FLOOD_NBYTES, qos=spec)
            pending.append(ev)
            counters["submitted"] += 1
    for ev in pending:  # drain: leave no queued flood work behind
        ev.wait(10.0)
    counters["depth"] = 0


def _run_variant(name: str, *, flood: bool, tenant_fair: bool,
                 cap_admit: bool = False, quick: bool = False) -> dict:
    n_events = 60 if quick else 400
    rng = np.random.default_rng(0)
    qos = {CLS: ClassQos(weight=1.0, deadline_s=60.0)}
    counters = {"submitted": 0, "sheds": 0, "depth": 0}
    waits: list[float] = []
    with TransferRuntime(workers=1, qos=qos,
                         tenant_fair=tenant_fair) as rt:
        # measure arbitration, not completion batching: immediate wakeups
        rt.set_coalesce(CLS, None)
        h = rt.register(f"bench-{name}", CLS)
        admission = None
        if cap_admit:
            rt.set_tenant_cap(CLS, "flood", FLOOD_CAP_BPS, burst_s=0.005)
            admission = AdmissionController(
                runtime=rt, cls=CLS,
                policy=AdmissionPolicy(queue_depth=8, shed_depth=24))
        stop = threading.Event()
        flooder = None
        if flood:
            flooder = threading.Thread(
                target=_flood_loop, args=(h, rt, stop, counters, admission),
                daemon=True)
            flooder.start()
            # let the flood backlog actually build before measuring
            t0 = time.monotonic()
            while (counters["depth"] < FLOOD_DEPTH // 2
                   and time.monotonic() - t0 < 2.0):
                time.sleep(1e-3)
        for _ in range(4):  # warmup: worker spin-up + first dispatches
            ev, _ = h.submit(lambda: time.sleep(VICTIM_SERVICE_S),
                             nbytes=VICTIM_NBYTES,
                             qos=QosSpec(tenant=_victim_tenant(rng)))
            ev.wait()
        for _ in range(n_events):
            spec = QosSpec(tenant=_victim_tenant(rng))
            t0 = time.perf_counter()
            ev, _ = h.submit(lambda: time.sleep(VICTIM_SERVICE_S),
                             nbytes=VICTIM_NBYTES, qos=spec)
            ev.wait()
            waits.append(time.perf_counter() - t0)
        stop.set()
        if flooder is not None:
            flooder.join(timeout=30)
        summary = rt.class_summary().get(CLS.value, {})
        tenants = summary.get("tenants", {})
        flood_row = tenants.get("flood", {})
        h.close()
    return {
        "bench": "tenant_isolation",
        "variant": name,
        "n_victim_events": n_events,
        "n_tenants": N_TENANTS,
        "tenants_active": len(tenants),
        "victim_p50_ms": round(_pct(waits, 0.5) * 1e3, 3),
        "victim_p99_ms": round(_pct(waits, 0.99) * 1e3, 3),
        "victim_max_ms": round(max(waits) * 1e3, 3),
        "flood_submitted": counters["submitted"],
        "flood_completed": int(flood_row.get("completed", 0)),
        "flood_cap_deferrals": int(flood_row.get("cap_deferrals", 0)),
        "admission_sheds": counters["sheds"],
    }


def run(quick: bool = False) -> list[dict]:
    rows = [
        _run_variant("noflood", flood=False, tenant_fair=True, quick=quick),
        _run_variant("flood-single", flood=True, tenant_fair=False,
                     quick=quick),
        _run_variant("flood-wfq", flood=True, tenant_fair=True, quick=quick),
        _run_variant("flood-cap-admit", flood=True, tenant_fair=True,
                     cap_admit=True, quick=quick),
    ]
    by = {r["variant"]: r for r in rows}
    base = max(by["noflood"]["victim_p99_ms"], 1e-6)
    rows.append({
        "bench": "tenant_isolation",
        "variant": "headline",
        "isolation_ratio_wfq": round(
            by["flood-wfq"]["victim_p99_ms"] / base, 3),
        "isolation_ratio_single_tier": round(
            by["flood-single"]["victim_p99_ms"] / base, 3),
        "isolation_ratio_cap_admit": round(
            by["flood-cap-admit"]["victim_p99_ms"] / base, 3),
    })
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path = BENCH_JSON) -> dict:
    doc = json.loads(path.read_text()) if path.exists() else {}
    by = {r["variant"]: r for r in rows}
    head = by["headline"]
    doc["tenant_isolation"] = {
        "rows": rows,
        "n_tenants": N_TENANTS,
        "victim_p99_noflood_ms": by["noflood"]["victim_p99_ms"],
        "victim_p99_flood_wfq_ms": by["flood-wfq"]["victim_p99_ms"],
        "victim_p99_flood_single_ms": by["flood-single"]["victim_p99_ms"],
        "victim_p99_flood_cap_admit_ms":
            by["flood-cap-admit"]["victim_p99_ms"],
        "isolation_ratio_wfq": head["isolation_ratio_wfq"],
        "isolation_ratio_single_tier": head["isolation_ratio_single_tier"],
        "isolation_ratio_cap_admit": head["isolation_ratio_cap_admit"],
        "flood_cap_deferrals": by["flood-cap-admit"]["flood_cap_deferrals"],
        "admission_sheds": by["flood-cap-admit"]["admission_sheds"],
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc["tenant_isolation"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer victim events; do NOT rewrite BENCH json")
    args = ap.parse_args()
    rows = run(quick=args.quick)
    keys = ["variant", "victim_p50_ms", "victim_p99_ms", "victim_max_ms",
            "tenants_active", "flood_completed", "flood_cap_deferrals",
            "admission_sheds"]
    print(",".join(keys))
    for r in rows[:-1]:
        print(",".join(str(r[k]) for k in keys))
    head = rows[-1]
    print(f"victim p99 degradation vs noflood: "
          f"wfq {head['isolation_ratio_wfq']}x, "
          f"single-tier {head['isolation_ratio_single_tier']}x, "
          f"cap+admit {head['isolation_ratio_cap_admit']}x")
    if not args.quick:
        merge_bench_json(rows)
        print(f"merged into {BENCH_JSON}")


if __name__ == "__main__":
    main()
