"""Token-RX latency under bulk contention: shared QoS runtime vs baselines.

The PR-4 acceptance scenario, measured: a serving stream's token-sized RX
(TOKEN class) competes with continuous bulk layer TX (LAYER class) for
completion dispatch — the paper's 'interrupt controller arbitrates DMA
against everything else' situation. Three dispatch regimes:

- ``runtime-arbitrated`` — both engines share ONE
  :class:`~repro.core.runtime.TransferRuntime` (2 workers) with
  deadline-aware weighted-fair arbitration: a token descriptor jumps the
  bulk backlog, so its latency is bounded by the in-service chunk, not
  the queue.
- ``per-engine-pool`` — each engine gets its own private runtime (2
  workers each), reproducing the retired per-engine ``_CompletionPool``
  world: the token stream owns dedicated workers but the host pays 2x
  the threads (oversubscription on a small host).
- ``shared-fifo`` — one shared runtime with arbitration disabled
  (``fair=False``): the naive shared pool, where the token waits out the
  whole bulk backlog. This is the regime QoS arbitration exists to kill.

Preemptive chunked dispatch (PR 5) adds the single-worker pair that
isolates the mechanism the reserved lane cannot provide — a worker
mid-chunk is non-preemptive, so when NO worker is free the token waits
out a whole in-service bulk chunk:

- ``no-preempt-1w`` — one shared worker (the lane needs >= 2 workers, so
  head-of-line blocking is structural): token p99 ~ one 2 MiB chunk.
- ``preempt-1w`` — same single worker, but bulk chunks are submitted as
  resumable segment iterators sized by the fitted cost model
  (``TransferCostModel.preempt_chunk_bytes``): the worker parks the bulk
  chunk at the next segment boundary the moment the token arrives.

A cap sweep (PR 5) measures the per-class bandwidth ceiling: BULK + LAYER
floods share one runtime, first uncapped, then with BULK capped to 50% of
its measured uncapped rate — the byte shares in ``class_summary()`` must
shift toward the uncapped class.

Headline: p99 token-RX latency, runtime-arbitrated must be no worse than
per-engine-pool (acceptance) and far below shared-fifo; preempt-1w must
beat no-preempt-1w (mechanism) and the PR-4 reserved-lane baseline
(acceptance) with HALF its workers. Each variant runs ``REPS`` times; the
reported p50/p99 are medians across reps (one scheduler hiccup must not
swing the comparison on this 2-core host).

Results merge into ``BENCH_transfer.json`` under ``"qos_contention"``.
``--quick`` shrinks iteration counts for the CI smoke (no JSON rewrite).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time

import numpy as np

from repro.core.channels import calibrate_transfer
from repro.core.runtime import PriorityClass, TransferRuntime, _pct
from repro.core.transfer import TransferEngine, TransferPolicy

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"

# One bulk layer payload = one 8 MiB chunk: a worker holds it in service
# for ~10 ms on this host (misaligned-copy path, ~0.85 GB/s — see
# _bulk_payload) — far above the ~1 ms OS scheduling noise floor, so the
# structural head-of-line penalty (a token waiting out a whole in-service
# chunk) dominates the measured tail instead of drowning in it.
BULK_BYTES = 8 << 20
BULK_BLOCK = 8 << 20
BULK_RING = 8              # deep ring: a real backlog forms in the queue
TOKEN_ELEMS = 8            # a decode step's token batch (8 x int32)
TOKEN_PERIOD_S = 2e-3      # decode cadence (>= the host's sleep floor)
# preemption segments: bounded service-time target for the fitted sizing,
# clamped to [block/8, block/4] (~1-2.5 ms of service each here). The
# clamp matters on this backend: every extra device_put pays a real fixed
# dispatch cost (~0.2-0.5 ms measured) that the linear fit underestimates,
# so unclamped fitted segments would tank bulk throughput; and a fit whose
# outlier fallback inflated t0 would otherwise produce segments bigger
# than the chunk and silently measure nothing.
PREEMPT_TARGET_S = 1e-3
PREEMPT_MIN_SEG = BULK_BLOCK // 8
PREEMPT_MAX_SEG = BULK_BLOCK // 4


def _bulk_payload(rng: np.random.Generator, nbytes: int) -> np.ndarray:
    """A flood payload whose device_put ALWAYS performs the copy: a
    deliberately MISALIGNED view (base + 1 byte) can never take the CPU
    backend's zero-copy path, which wants 64-byte-aligned data. Without
    this, some runs intermittently zero-copied the flood (~40 "GB/s" of
    no-op transfers) and the contention being measured dissolved."""
    buf = rng.integers(0, 255, nbytes + 1, dtype=np.uint8)
    return buf[1:1 + nbytes]


def _bulk_policy(preempt_bytes: int = 0,
                 completion_workers: int = 2) -> TransferPolicy:
    return TransferPolicy.kernel_level_ring(
        BULK_RING, block_bytes=BULK_BLOCK).with_(
            preempt_chunk_bytes=preempt_bytes,
            completion_workers=completion_workers)


def fitted_preempt_bytes() -> int:
    """Segment size from the fitted cost model, clamped for the demo."""
    model = calibrate_transfer()
    seg = model.preempt_chunk_bytes(PREEMPT_TARGET_S)
    return min(max(seg, PREEMPT_MIN_SEG), PREEMPT_MAX_SEG)


def _measure_variant(runtime_for, label: str, n_tokens: int,
                     warmup: int, bulk_policy: TransferPolicy | None = None,
                     token_policy: TransferPolicy | None = None) -> dict:
    """Run bulk TX flood + periodic token RX; return latency stats.

    ``runtime_for(stream)`` maps "bulk"/"token" to the runtime that stream's
    engine should dispatch on (same object = shared)."""
    rt_bulk = runtime_for("bulk")
    rt_token = runtime_for("token")
    bulk_eng = TransferEngine(bulk_policy or _bulk_policy(), runtime=rt_bulk,
                              priority=PriorityClass.LAYER)
    token_eng = TransferEngine(token_policy or TransferPolicy.kernel_level(),
                               runtime=rt_token,
                               priority=PriorityClass.TOKEN)
    rng = np.random.default_rng(0)
    bulk_payload = _bulk_payload(rng, BULK_BYTES)
    tok_dev = token_eng.tx(np.arange(TOKEN_ELEMS, dtype=np.int32))
    tok_out = np.empty(TOKEN_ELEMS, np.int32)
    # warm both paths (first device_put pays one-time dispatch/alloc costs)
    token_eng.rx_async(tok_dev, out=[tok_out],
                       priority=PriorityClass.TOKEN).wait()
    bulk_eng.tx_async(bulk_payload[: 1 << 20]).wait()

    stop = threading.Event()
    bulk_bytes = {"n": 0}

    def bulk_flood() -> None:
        # keep two striped payloads outstanding so the runtime queue never
        # drains: contention is continuous for the whole token window
        pending = []
        while not stop.is_set():
            pending.append(bulk_eng.tx_async(bulk_payload))
            if len(pending) >= 2:
                pending.pop(0).wait()
                bulk_bytes["n"] += BULK_BYTES
        for t in pending:
            t.wait()
            bulk_bytes["n"] += BULK_BYTES

    flood = threading.Thread(target=bulk_flood, daemon=True)
    flood.start()
    time.sleep(0.02)  # let the backlog form

    lats: list[float] = []
    t_start = time.perf_counter()
    for i in range(warmup + n_tokens):
        t0 = time.perf_counter()
        token_eng.rx_async(tok_dev, out=[tok_out],
                           priority=PriorityClass.TOKEN).wait()
        lat = time.perf_counter() - t0
        if i >= warmup:
            lats.append(lat)
        time.sleep(TOKEN_PERIOD_S)
    stop.set()
    flood.join(timeout=30)
    # window closes AFTER the flood drained: the tail payloads' bytes are
    # in the numerator, so their completion time must be in the
    # denominator too, or bulk_gbps is inflated.
    window_s = time.perf_counter() - t_start
    # preemption ledger BEFORE close (close drains/deregisters the engines)
    flood_cls = rt_bulk.class_summary().get(PriorityClass.LAYER.value, {})
    park_p99 = flood_cls.get("preempt_park_p99_ms", float("nan"))
    bulk_eng.close()
    token_eng.close()
    return {
        "bench": "qos_contention",
        "variant": label,
        "token_rx_p50_ms": round(_pct(lats, 0.5) * 1e3, 4),
        "token_rx_p99_ms": round(_pct(lats, 0.99) * 1e3, 4),
        "token_rx_max_ms": round(max(lats) * 1e3, 4),
        "n_tokens": len(lats),
        "bulk_gbps": round(bulk_bytes["n"] / max(window_s, 1e-9) / 1e9, 3),
        "flood_preemptions": int(flood_cls.get("preemptions", 0)),
        # None (not NaN) when the variant never preempted: a bare NaN
        # token would make the merged BENCH_transfer.json invalid JSON
        # for strict (non-Python) consumers of the CI artifact.
        "preempt_park_p99_ms": (round(park_p99, 4)
                                if park_p99 == park_p99 else None),
    }


def _median_rows(rows: list[dict]) -> dict:
    """Median per-field across one variant's repetitions."""
    out = dict(rows[0])
    for k in ("token_rx_p50_ms", "token_rx_p99_ms", "token_rx_max_ms",
              "bulk_gbps", "flood_preemptions", "preempt_park_p99_ms"):
        vals = [v for r in rows
                if isinstance(v := r.get(k), (int, float)) and v == v]
        if vals:
            out[k] = sorted(vals)[len(vals) // 2]
    return out


def _measure_cap_sweep(seconds: float, cap_frac: float = 0.5) -> list[dict]:
    """BULK + LAYER TX floods on one runtime: byte shares uncapped, then
    with BULK capped to ``cap_frac`` of its measured uncapped rate. The
    cap must measurably shift bytes to the uncapped class."""

    def flood_phase(cap_Bps: float | None) -> dict:
        rt = TransferRuntime(workers=2)
        pol = _bulk_policy()
        engines = {
            PriorityClass.BULK: TransferEngine(pol, runtime=rt,
                                               priority=PriorityClass.BULK),
            PriorityClass.LAYER: TransferEngine(pol, runtime=rt,
                                                priority=PriorityClass.LAYER),
        }
        if cap_Bps is not None:
            rt.set_class_cap(PriorityClass.BULK, cap_Bps)
        rng = np.random.default_rng(1)
        payload = _bulk_payload(rng, 8 << 20)
        for eng in engines.values():  # warm the device path
            eng.tx_async(payload[: 1 << 20]).wait()
        deadline = time.perf_counter() + seconds
        done = {cls: 0 for cls in engines}

        def flood(cls: PriorityClass) -> None:
            eng = engines[cls]
            pending = []
            while time.perf_counter() < deadline:
                pending.append(eng.tx_async(payload))
                if len(pending) >= 2:
                    pending.pop(0).wait()
                    done[cls] += payload.nbytes
            for t in pending:
                t.wait()
                done[cls] += payload.nbytes

        t0 = time.perf_counter()
        threads = [threading.Thread(target=flood, args=(cls,), daemon=True)
                   for cls in engines]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        window = time.perf_counter() - t0
        summary = rt.class_summary()
        for eng in engines.values():
            eng.close()
        rt.close()
        bulk_b = done[PriorityClass.BULK]
        layer_b = done[PriorityClass.LAYER]
        return {
            "bench": "qos_contention",
            "variant": "cap-off" if cap_Bps is None else "cap-50pct",
            "cap_bytes_per_s": cap_Bps,
            "bulk_gbps": round(bulk_b / max(window, 1e-9) / 1e9, 3),
            "layer_gbps": round(layer_b / max(window, 1e-9) / 1e9, 3),
            "bulk_share": round(bulk_b / max(bulk_b + layer_b, 1), 3),
            "bulk_cap_deferrals": int(
                summary.get("bulk", {}).get("cap_deferrals", 0)),
        }

    uncapped = flood_phase(None)
    cap_Bps = cap_frac * uncapped["bulk_gbps"] * 1e9
    capped = flood_phase(max(cap_Bps, 1e6))
    return [uncapped, capped]


COALESCE_DESCS = 32        # one sweep = 32 token RX descriptors...
COALESCE_ELEMS = 1024      # ...of 4 KiB each (1024 x int32)


def _measure_coalescing_sweep(reps: int) -> list[dict]:
    """Batched-submission amortization, measured: 32 token-sized RX
    descriptors go down as 32 pipelined ``rx_async`` (batch 1), four
    ``rx_many`` groups of 8, and one ``rx_many`` group of 32 — same
    payloads, same runtime, same ring. The per-descriptor wall time is
    the management-overhead curve the paper's Fig. 4/5 is about; the
    headline ``speedup_b32`` is the amortization factor batching buys
    on packets this small."""
    batches = (1, 8, 32)
    per_batch: dict[int, list[dict]] = {b: [] for b in batches}
    for _rep in range(reps):
        for b in batches:
            rt = TransferRuntime(workers=2)
            eng = TransferEngine(
                TransferPolicy.kernel_level_ring(8),
                runtime=rt, priority=PriorityClass.TOKEN)
            arrays = [np.arange(COALESCE_ELEMS, dtype=np.int32) + i
                      for i in range(COALESCE_DESCS)]
            devs = [t.wait(30.0) for t in eng.tx_many(arrays)]
            outs = [np.empty(COALESCE_ELEMS, np.int32) for _ in arrays]
            # warm the RX path (first device_get pays one-time costs)
            eng.rx_many(devs[:2], out=outs[:2])[1].wait(30.0)
            t0 = time.perf_counter()
            if b == 1:
                tickets = [eng.rx_async([d], out=[o],
                                        priority=PriorityClass.TOKEN)
                           for d, o in zip(devs, outs)]
            else:
                tickets = []
                for i in range(0, COALESCE_DESCS, b):
                    tickets.extend(eng.rx_many(
                        devs[i:i + b], out=outs[i:i + b],
                        priority=PriorityClass.TOKEN))
            for t in tickets:
                t.wait(30.0)
            wall = time.perf_counter() - t0
            tok_cls = rt.class_summary().get(PriorityClass.TOKEN.value, {})
            eng.close()
            rt.close()
            per_batch[b].append({
                "bench": "qos_contention",
                "variant": f"coalesce-b{b}",
                "batch": b,
                "n_desc": COALESCE_DESCS,
                "desc_bytes": COALESCE_ELEMS * 4,
                "per_desc_us": round(wall / COALESCE_DESCS * 1e6, 2),
                "wall_ms": round(wall * 1e3, 3),
                "wakeups_saved": int(tok_cls.get("wakeups_saved", 0)),
            })
    rows = []
    for b in batches:
        rs = per_batch[b]
        med = dict(sorted(rs, key=lambda r: r["per_desc_us"])[len(rs) // 2])
        rows.append(med)
    b1 = next(r for r in rows if r["batch"] == 1)
    b8 = next(r for r in rows if r["batch"] == 8)
    b32 = next(r for r in rows if r["batch"] == 32)
    rows.append({
        "bench": "qos_contention",
        "variant": "coalesce-headline",
        # acceptance: batched submission amortizes per-descriptor
        # management overhead by >= 2x at batch 32 on 4 KiB payloads
        "speedup_b8": round(
            b1["per_desc_us"] / max(b8["per_desc_us"], 1e-9), 3),
        "speedup_b32": round(
            b1["per_desc_us"] / max(b32["per_desc_us"], 1e-9), 3),
    })
    return rows


def run(quick: bool = False) -> list[dict]:
    n_tokens = 40 if quick else 150
    warmup = 5 if quick else 15
    # medians over 5 reps: p99 on a 2-core host needs more than 3 samples
    # before one scheduler hiccup stops swinging the headline ratios.
    reps = 1 if quick else 5
    cap_seconds = 0.5 if quick else 2.0
    preempt_bytes = fitted_preempt_bytes()

    def shared_factory():
        rt = TransferRuntime(workers=2)
        return lambda stream: rt, [rt]

    def per_engine_factory():
        rts = {"bulk": TransferRuntime(workers=2),
               "token": TransferRuntime(workers=2)}
        return lambda stream: rts[stream], list(rts.values())

    def fifo_factory():
        rt = TransferRuntime(workers=2, fair=False)
        return lambda stream: rt, [rt]

    def one_worker_factory():
        # a single shared worker: the reserved lane is structurally
        # impossible (it needs a worker to spare), so the token's wait is
        # bounded ONLY by the in-service dispatch unit — whole chunk
        # without preemption, one fitted segment with it.
        rt = TransferRuntime(workers=1)
        return lambda stream: rt, [rt]

    # completion_workers=1 so the engines' workers_hint cannot grow the
    # single-worker runtimes back to 2.
    p1_bulk_plain = _bulk_policy(0, completion_workers=1)
    p1_bulk_pre = _bulk_policy(preempt_bytes, completion_workers=1)
    p1_token = TransferPolicy.kernel_level().with_(completion_workers=1)
    variants = [
        ("runtime-arbitrated", shared_factory, None, None),
        ("per-engine-pool", per_engine_factory, None, None),
        ("shared-fifo", fifo_factory, None, None),
        ("no-preempt-1w", one_worker_factory, p1_bulk_plain, p1_token),
        ("preempt-1w", one_worker_factory, p1_bulk_pre, p1_token),
    ]

    rows: list[dict] = []
    per_variant: dict[str, list[dict]] = {}
    for rep in range(reps):
        for label, make, bulk_pol, tok_pol in variants:
            runtime_for, rts = make()
            row = _measure_variant(runtime_for, label, n_tokens, warmup,
                                   bulk_policy=bulk_pol,
                                   token_policy=tok_pol)
            for rt in rts:
                rt.close()
            per_variant.setdefault(label, []).append(row)
    for label, *_ in variants:
        rows.append(_median_rows(per_variant[label]))

    arb = next(r for r in rows if r["variant"] == "runtime-arbitrated")
    pep = next(r for r in rows if r["variant"] == "per-engine-pool")
    fifo = next(r for r in rows if r["variant"] == "shared-fifo")
    hol = next(r for r in rows if r["variant"] == "no-preempt-1w")
    pre = next(r for r in rows if r["variant"] == "preempt-1w")
    rows.append({
        "bench": "qos_contention",
        "variant": "headline",
        # acceptance: arbitrated p99 no worse than the per-engine baseline
        "p99_ratio_per_engine_over_runtime": round(
            pep["token_rx_p99_ms"] / max(arb["token_rx_p99_ms"], 1e-9), 3),
        # the regime arbitration exists to kill: naive shared FIFO
        "p99_ratio_fifo_over_runtime": round(
            fifo["token_rx_p99_ms"] / max(arb["token_rx_p99_ms"], 1e-9), 3),
        # preemptive chunking, mechanism isolated (same single worker)
        "p99_ratio_hol_over_preempt": round(
            hol["token_rx_p99_ms"] / max(pre["token_rx_p99_ms"], 1e-9), 3),
        # acceptance: preemption at ONE worker vs the PR-4 reserved-lane
        # baseline at TWO (>= 1 means preemptive chunking improves on it)
        "p99_ratio_reserved_lane_over_preempt": round(
            arb["token_rx_p99_ms"] / max(pre["token_rx_p99_ms"], 1e-9), 3),
        "preempt_chunk_bytes": preempt_bytes,
        "runtime_threads": 2,
        "per_engine_threads": 4,
    })
    rows.extend(_measure_cap_sweep(cap_seconds))
    rows.extend(_measure_coalescing_sweep(reps=1 if quick else 5))
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Fold the contention run into BENCH_transfer.json."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    head = next(r for r in rows if r["variant"] == "headline")
    arb = next(r for r in rows if r["variant"] == "runtime-arbitrated")
    pep = next(r for r in rows if r["variant"] == "per-engine-pool")
    fifo = next(r for r in rows if r["variant"] == "shared-fifo")
    hol = next(r for r in rows if r["variant"] == "no-preempt-1w")
    pre = next(r for r in rows if r["variant"] == "preempt-1w")
    cap_off = next(r for r in rows if r["variant"] == "cap-off")
    cap_on = next(r for r in rows if r["variant"] == "cap-50pct")
    doc["qos_contention"] = {
        "rows": rows,
        "runtime_arbitrated_token_rx_p99_ms": arb["token_rx_p99_ms"],
        "per_engine_pool_token_rx_p99_ms": pep["token_rx_p99_ms"],
        "shared_fifo_token_rx_p99_ms": fifo["token_rx_p99_ms"],
        "no_preempt_1w_token_rx_p99_ms": hol["token_rx_p99_ms"],
        "preempt_1w_token_rx_p99_ms": pre["token_rx_p99_ms"],
        "p99_ratio_per_engine_over_runtime":
            head["p99_ratio_per_engine_over_runtime"],
        "p99_ratio_fifo_over_runtime": head["p99_ratio_fifo_over_runtime"],
        "p99_ratio_hol_over_preempt": head["p99_ratio_hol_over_preempt"],
        "p99_ratio_reserved_lane_over_preempt":
            head["p99_ratio_reserved_lane_over_preempt"],
        "preempt_chunk_bytes": head["preempt_chunk_bytes"],
        "cap_bulk_share_uncapped": cap_off["bulk_share"],
        "cap_bulk_share_capped": cap_on["bulk_share"],
        "cap_layer_gbps_uncapped": cap_off["layer_gbps"],
        "cap_layer_gbps_capped": cap_on["layer_gbps"],
        "cap_bytes_per_s": cap_on["cap_bytes_per_s"],
    }
    co_rows = [r for r in rows if r["variant"].startswith("coalesce")]
    if co_rows:
        co_head = next(r for r in co_rows
                       if r["variant"] == "coalesce-headline")
        by_batch = {r["batch"]: r for r in co_rows if "batch" in r}
        doc["coalescing"] = {
            "rows": co_rows,
            "desc_bytes": by_batch[1]["desc_bytes"],
            "n_desc": by_batch[1]["n_desc"],
            "per_desc_us_b1": by_batch[1]["per_desc_us"],
            "per_desc_us_b8": by_batch[8]["per_desc_us"],
            "per_desc_us_b32": by_batch[32]["per_desc_us"],
            "speedup_b8": co_head["speedup_b8"],
            "speedup_b32": co_head["speedup_b32"],
        }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small iteration counts, no JSON rewrite (CI smoke)")
    args = ap.parse_args()
    bench_rows = run(quick=args.quick)
    for r in bench_rows:
        print(r)
    if not args.quick:
        doc = merge_bench_json(bench_rows)
        qc = doc["qos_contention"]
        print(f"wrote {BENCH_JSON}: token-RX p99 per-engine/runtime ratio "
              f"{qc['p99_ratio_per_engine_over_runtime']}, fifo/runtime "
              f"ratio {qc['p99_ratio_fifo_over_runtime']}, coalescing "
              f"b32 speedup {doc['coalescing']['speedup_b32']}x")
