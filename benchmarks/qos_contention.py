"""Token-RX latency under bulk contention: shared QoS runtime vs baselines.

The PR-4 acceptance scenario, measured: a serving stream's token-sized RX
(TOKEN class) competes with continuous bulk layer TX (LAYER class) for
completion dispatch — the paper's 'interrupt controller arbitrates DMA
against everything else' situation. Three dispatch regimes:

- ``runtime-arbitrated`` — both engines share ONE
  :class:`~repro.core.runtime.TransferRuntime` (2 workers) with
  deadline-aware weighted-fair arbitration: a token descriptor jumps the
  bulk backlog, so its latency is bounded by the in-service chunk, not
  the queue.
- ``per-engine-pool`` — each engine gets its own private runtime (2
  workers each), reproducing the retired per-engine ``_CompletionPool``
  world: the token stream owns dedicated workers but the host pays 2x
  the threads (oversubscription on a small host).
- ``shared-fifo`` — one shared runtime with arbitration disabled
  (``fair=False``): the naive shared pool, where the token waits out the
  whole bulk backlog. This is the regime QoS arbitration exists to kill.

Headline: p99 token-RX latency, runtime-arbitrated must be no worse than
per-engine-pool (acceptance) and far below shared-fifo. Each variant runs
``REPS`` times; the reported p50/p99 are medians across reps (one
scheduler hiccup must not swing the comparison on this 2-core host).

Results merge into ``BENCH_transfer.json`` under ``"qos_contention"``.
``--quick`` shrinks iteration counts for the CI smoke (no JSON rewrite).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time

import numpy as np

from repro.core.runtime import PriorityClass, TransferRuntime, _pct
from repro.core.transfer import TransferEngine, TransferPolicy

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"

BULK_BYTES = 16 << 20      # one bulk layer payload
BULK_BLOCK = 2 << 20       # 2 MiB chunks: each holds a worker for ~ms
BULK_RING = 8              # deep ring: a real backlog forms in the queue
TOKEN_ELEMS = 8            # a decode step's token batch (8 x int32)
TOKEN_PERIOD_S = 2e-3      # decode cadence (>= the host's sleep floor)


def _bulk_policy() -> TransferPolicy:
    return TransferPolicy.kernel_level_ring(BULK_RING, block_bytes=BULK_BLOCK)


def _measure_variant(runtime_for, label: str, n_tokens: int,
                     warmup: int) -> dict:
    """Run bulk TX flood + periodic token RX; return latency stats.

    ``runtime_for(stream)`` maps "bulk"/"token" to the runtime that stream's
    engine should dispatch on (same object = shared)."""
    rt_bulk = runtime_for("bulk")
    rt_token = runtime_for("token")
    bulk_eng = TransferEngine(_bulk_policy(), runtime=rt_bulk,
                              priority=PriorityClass.LAYER)
    token_eng = TransferEngine(TransferPolicy.kernel_level(),
                               runtime=rt_token,
                               priority=PriorityClass.TOKEN)
    rng = np.random.default_rng(0)
    bulk_payload = rng.integers(0, 255, BULK_BYTES, dtype=np.uint8)
    tok_dev = token_eng.tx(np.arange(TOKEN_ELEMS, dtype=np.int32))
    tok_out = np.empty(TOKEN_ELEMS, np.int32)
    # warm both paths (first device_put pays one-time dispatch/alloc costs)
    token_eng.rx_async(tok_dev, out=[tok_out],
                       priority=PriorityClass.TOKEN).wait()
    bulk_eng.tx_async(bulk_payload[: 1 << 20]).wait()

    stop = threading.Event()
    bulk_bytes = {"n": 0}

    def bulk_flood() -> None:
        # keep two striped payloads outstanding so the runtime queue never
        # drains: contention is continuous for the whole token window
        pending = []
        while not stop.is_set():
            pending.append(bulk_eng.tx_async(bulk_payload))
            if len(pending) >= 2:
                pending.pop(0).wait()
                bulk_bytes["n"] += BULK_BYTES
        for t in pending:
            t.wait()
            bulk_bytes["n"] += BULK_BYTES

    flood = threading.Thread(target=bulk_flood, daemon=True)
    flood.start()
    time.sleep(0.02)  # let the backlog form

    lats: list[float] = []
    t_start = time.perf_counter()
    for i in range(warmup + n_tokens):
        t0 = time.perf_counter()
        token_eng.rx_async(tok_dev, out=[tok_out],
                           priority=PriorityClass.TOKEN).wait()
        lat = time.perf_counter() - t0
        if i >= warmup:
            lats.append(lat)
        time.sleep(TOKEN_PERIOD_S)
    stop.set()
    flood.join(timeout=30)
    # window closes AFTER the flood drained: the tail payloads' bytes are
    # in the numerator, so their completion time must be in the
    # denominator too, or bulk_gbps is inflated.
    window_s = time.perf_counter() - t_start
    bulk_eng.close()
    token_eng.close()
    return {
        "bench": "qos_contention",
        "variant": label,
        "token_rx_p50_ms": round(_pct(lats, 0.5) * 1e3, 4),
        "token_rx_p99_ms": round(_pct(lats, 0.99) * 1e3, 4),
        "token_rx_max_ms": round(max(lats) * 1e3, 4),
        "n_tokens": len(lats),
        "bulk_gbps": round(bulk_bytes["n"] / max(window_s, 1e-9) / 1e9, 3),
    }


def _median_rows(rows: list[dict]) -> dict:
    """Median per-field across one variant's repetitions."""
    out = dict(rows[0])
    for k in ("token_rx_p50_ms", "token_rx_p99_ms", "token_rx_max_ms",
              "bulk_gbps"):
        out[k] = sorted(r[k] for r in rows)[len(rows) // 2]
    return out


def run(quick: bool = False) -> list[dict]:
    n_tokens = 40 if quick else 150
    warmup = 5 if quick else 15
    reps = 1 if quick else 3

    def shared_factory():
        rt = TransferRuntime(workers=2)
        return lambda stream: rt, [rt]

    def per_engine_factory():
        rts = {"bulk": TransferRuntime(workers=2),
               "token": TransferRuntime(workers=2)}
        return lambda stream: rts[stream], list(rts.values())

    def fifo_factory():
        rt = TransferRuntime(workers=2, fair=False)
        return lambda stream: rt, [rt]

    variants = [
        ("runtime-arbitrated", shared_factory),
        ("per-engine-pool", per_engine_factory),
        ("shared-fifo", fifo_factory),
    ]

    rows: list[dict] = []
    per_variant: dict[str, list[dict]] = {}
    for rep in range(reps):
        for label, make in variants:
            runtime_for, rts = make()
            row = _measure_variant(runtime_for, label, n_tokens, warmup)
            for rt in rts:
                rt.close()
            per_variant.setdefault(label, []).append(row)
    for label, _ in variants:
        rows.append(_median_rows(per_variant[label]))

    arb = next(r for r in rows if r["variant"] == "runtime-arbitrated")
    pep = next(r for r in rows if r["variant"] == "per-engine-pool")
    fifo = next(r for r in rows if r["variant"] == "shared-fifo")
    rows.append({
        "bench": "qos_contention",
        "variant": "headline",
        # acceptance: arbitrated p99 no worse than the per-engine baseline
        "p99_ratio_per_engine_over_runtime": round(
            pep["token_rx_p99_ms"] / max(arb["token_rx_p99_ms"], 1e-9), 3),
        # the regime arbitration exists to kill: naive shared FIFO
        "p99_ratio_fifo_over_runtime": round(
            fifo["token_rx_p99_ms"] / max(arb["token_rx_p99_ms"], 1e-9), 3),
        "runtime_threads": 2,
        "per_engine_threads": 4,
    })
    return rows


def merge_bench_json(rows: list[dict],
                     path: pathlib.Path | str = BENCH_JSON) -> dict:
    """Fold the contention run into BENCH_transfer.json."""
    path = pathlib.Path(path)
    doc = json.loads(path.read_text()) if path.exists() else {}
    head = next(r for r in rows if r["variant"] == "headline")
    arb = next(r for r in rows if r["variant"] == "runtime-arbitrated")
    pep = next(r for r in rows if r["variant"] == "per-engine-pool")
    fifo = next(r for r in rows if r["variant"] == "shared-fifo")
    doc["qos_contention"] = {
        "rows": rows,
        "runtime_arbitrated_token_rx_p99_ms": arb["token_rx_p99_ms"],
        "per_engine_pool_token_rx_p99_ms": pep["token_rx_p99_ms"],
        "shared_fifo_token_rx_p99_ms": fifo["token_rx_p99_ms"],
        "p99_ratio_per_engine_over_runtime":
            head["p99_ratio_per_engine_over_runtime"],
        "p99_ratio_fifo_over_runtime": head["p99_ratio_fifo_over_runtime"],
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small iteration counts, no JSON rewrite (CI smoke)")
    args = ap.parse_args()
    bench_rows = run(quick=args.quick)
    for r in bench_rows:
        print(r)
    if not args.quick:
        doc = merge_bench_json(bench_rows)
        qc = doc["qos_contention"]
        print(f"wrote {BENCH_JSON}: token-RX p99 per-engine/runtime ratio "
              f"{qc['p99_ratio_per_engine_over_runtime']}, fifo/runtime "
              f"ratio {qc['p99_ratio_fifo_over_runtime']}")
