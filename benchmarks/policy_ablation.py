"""Buffering x partitioning ablation (the paper's single/double buffer and
Unique/Blocks comparison) at three payload sizes, INTERRUPT management."""

from __future__ import annotations

import numpy as np

from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)
from repro.utils.timing import bench

SIZES = [64 << 10, 1 << 20, 6 << 20]


def run(iters: int = 5) -> list[dict]:
    rows = []
    for nbytes in SIZES:
        x = np.zeros(nbytes // 4, np.float32)
        for buf in Buffering:
            for part in Partitioning:
                policy = TransferPolicy(Management.INTERRUPT, buf, part,
                                        block_bytes=256 << 10)

                def one(x=x, policy=policy):
                    eng = TransferEngine(policy)
                    eng.rx(eng.tx(x))

                t = bench(one, warmup=2, iters=iters)
                rows.append({
                    "bench": "policy_ablation", "bytes": x.nbytes,
                    "buffering": buf.value, "partitioning": part.value,
                    "roundtrip_ms": round(t.median_s * 1e3, 4),
                })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
