"""Buffering x partitioning ablation (the paper's single/double buffer and
Unique/Blocks comparison) at three payload sizes, INTERRUPT management —
extended with descriptor-ring depths 3/4/8 (the generalisation of
single/double to an N-deep scatter-gather ring)."""

from __future__ import annotations

import numpy as np

from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)
from repro.utils.timing import bench

SIZES = [64 << 10, 1 << 20, 6 << 20]
RING_DEPTHS = [3, 4, 8]


def _measure(x: np.ndarray, policy: TransferPolicy, iters: int) -> float:
    def one(x=x, policy=policy):
        eng = TransferEngine(policy)
        eng.rx(eng.tx(x))
        eng.close()

    return bench(one, warmup=2, iters=iters).median_s


def run(iters: int = 5) -> list[dict]:
    rows = []
    for nbytes in SIZES:
        x = np.zeros(nbytes // 4, np.float32)
        for buf in (Buffering.SINGLE, Buffering.DOUBLE):
            for part in Partitioning:
                policy = TransferPolicy(Management.INTERRUPT, buf, part,
                                        block_bytes=256 << 10)
                rows.append({
                    "bench": "policy_ablation", "bytes": x.nbytes,
                    "buffering": buf.value, "partitioning": part.value,
                    "depth": policy.depth,
                    "roundtrip_ms": round(_measure(x, policy, iters) * 1e3, 4),
                })
        for depth in RING_DEPTHS:
            policy = TransferPolicy(Management.INTERRUPT, Buffering.RING,
                                    Partitioning.BLOCKS,
                                    block_bytes=256 << 10, ring_depth=depth)
            rows.append({
                "bench": "policy_ablation", "bytes": x.nbytes,
                "buffering": Buffering.RING.value,
                "partitioning": Partitioning.BLOCKS.value, "depth": depth,
                "roundtrip_ms": round(_measure(x, policy, iters) * 1e3, 4),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
