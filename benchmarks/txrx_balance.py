"""Scenario 1 (loop-back) reproduction: simultaneous TX and RX streams
contending for the host memory system. The paper's observation: TX gets
slight priority; unbalanced streams can block a single-buffered system.

We run a loop-back pipeline (tx chunk -> device -> rx chunk) with both
directions active and measure per-direction throughput under each policy."""

from __future__ import annotations

import numpy as np

from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)


def run(total_mb: int = 32) -> list[dict]:
    rows = []
    payload = np.zeros((1 << 20) // 4, np.float32)  # 1 MiB chunks
    n = total_mb
    for name, policy in [
        ("polling", TransferPolicy.user_level_polling()),
        ("interrupt-double-blocks", TransferPolicy(
            Management.INTERRUPT, Buffering.DOUBLE, Partitioning.BLOCKS,
            block_bytes=256 << 10)),
    ]:
        eng = TransferEngine(policy)
        # loop-back: every chunk goes out and comes straight back
        import time
        t0 = time.perf_counter()
        for _ in range(n):
            dev = eng.tx(payload)
            eng.rx(dev)
        wall = time.perf_counter() - t0
        s = eng.summary()
        rows.append({
            "bench": "txrx_balance", "driver": name,
            "total_mb": n, "wall_s": round(wall, 4),
            "tx_gbps": round(s["tx"]["gbps"], 3),
            "rx_gbps": round(s["rx"]["gbps"], 3),
            "tx_faster_than_rx": bool(s["tx"]["gbps"] > s["rx"]["gbps"]),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
