"""Scenario 1 (loop-back) reproduction: simultaneous TX and RX streams
contending for the host memory system. The paper's observation: TX gets
slight priority; unbalanced streams can block a single-buffered system.

We run a loop-back pipeline (tx chunk -> device -> rx chunk) with both
directions active and measure per-direction throughput under each policy.
The ring variant additionally overlaps the RX of round k with the TX of
round k+1 via ``rx_async`` (three-way overlap minus the compute leg)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.transfer import (
    Buffering,
    Management,
    Partitioning,
    TransferEngine,
    TransferPolicy,
)


def run(total_mb: int = 32) -> list[dict]:
    rows = []
    payload = np.zeros((1 << 20) // 4, np.float32)  # 1 MiB chunks
    n = total_mb
    for name, policy, overlap_rx in [
        ("polling", TransferPolicy.user_level_polling(), False),
        ("interrupt-double-blocks", TransferPolicy(
            Management.INTERRUPT, Buffering.DOUBLE, Partitioning.BLOCKS,
            block_bytes=256 << 10), False),
        ("interrupt-ring4-overlapped", TransferPolicy.kernel_level_ring(
            4, block_bytes=256 << 10), True),
    ]:
        eng = TransferEngine(policy)
        t0 = time.perf_counter()
        if overlap_rx:
            # loop-back with RX on a completion worker: round k's RX drains
            # while round k+1's TX streams (balanced TX/RX).
            pending = None
            for _ in range(n):
                dev = eng.tx(payload)
                if pending is not None:
                    pending.wait()
                pending = eng.rx_async(dev)
            pending.wait()
        else:
            for _ in range(n):
                dev = eng.tx(payload)
                eng.rx(dev)
        wall = time.perf_counter() - t0
        s = eng.summary()
        rows.append({
            "bench": "txrx_balance", "driver": name,
            "total_mb": n, "wall_s": round(wall, 4),
            "mb_per_s": round(n / max(wall, 1e-9), 2),
            "tx_gbps": round(s["tx"]["gbps"], 3),
            "rx_gbps": round(s["rx"]["gbps"], 3),
            "tx_faster_than_rx": bool(s["tx"]["gbps"] > s["rx"]["gbps"]),
        })
        eng.close()
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
