"""Benchmark runner — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only transfer_sweep,...]

Prints ``name,us_per_call,derived`` CSV rows (plus the full dict per row on
stderr-like detail lines prefixed '#').
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    adaptive_drift,
    collective_overlap,
    fault_recovery,
    multichannel_sweep,
    policy_ablation,
    qos_contention,
    roofline,
    roshambo_table,
    sg_vs_pack,
    streaming_layers,
    tenant_isolation,
    transfer_sweep,
    txrx_balance,
)

BENCHES = {
    "transfer_sweep": transfer_sweep.run,  # Fig 4 / Fig 5
    "roshambo_table": roshambo_table.run,  # Table I
    "policy_ablation": policy_ablation.run,  # single/double x unique/blocks
    "txrx_balance": txrx_balance.run,  # loop-back scenario
    "streaming_layers": streaming_layers.run,  # NullHop model at LM scale
    "multichannel_sweep": multichannel_sweep.run,  # striped rings + adaptive
    "sg_vs_pack": sg_vs_pack.run,  # scatter-gather vs staging-copy pack
    "adaptive_drift": adaptive_drift.run,  # online refit vs stale plan
    "qos_contention": qos_contention.run,  # shared-runtime QoS arbitration
    "tenant_isolation": tenant_isolation.run,  # tier-2 heavy-hitter WFQ
    "fault_recovery": fault_recovery.run,  # quarantine + replan vs stall
    "collective_overlap": collective_overlap.run,  # blocks-mode collectives
    "roofline": roofline.run,  # reads dry-run artifacts
}


def _derived(row: dict) -> str:
    for k in ("tx_us_per_byte", "roundtrip_ms", "frame_ms",
              "dominant_term_s", "collective_bytes_per_dev", "tx_gbps",
              "token_rx_p99_ms"):
        if k in row:
            return f"{k}={row[k]}"
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(
        BENCHES)

    failures: list[str] = []
    print("name,us_per_call,derived")
    for name in names:
        fn = BENCHES[name]
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},nan,error={type(e).__name__}")
            print(f"# {name} ERROR: {e}", file=sys.stderr)
            failures.append(name)
            continue
        us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        for row in rows:
            print(f"# {row}")
        print(f"{name},{us:.1f},{_derived(rows[0]) if rows else ''}")
        try:
            if name == "streaming_layers":
                doc = streaming_layers.write_bench_json(rows)
                print(f"# wrote BENCH_transfer.json (ring/seed frames_per_s "
                      f"ratio {doc['frames_per_s_ratio_ring_over_seed']})")
            if name == "multichannel_sweep":
                doc = multichannel_sweep.merge_bench_json(rows)
                mc = doc["multichannel"]
                print(f"# merged multichannel rows into BENCH_transfer.json "
                      f"(single-ring/multi tx us/B ratio "
                      f"{mc['tx_us_per_byte_ratio_single_ring_over_multi']})")
            if name == "sg_vs_pack":
                doc = sg_vs_pack.merge_bench_json(rows)
                sc = doc["staging_copy"]
                print(f"# merged sg_vs_pack rows into BENCH_transfer.json "
                      f"(few-large pack/SG tx us/B ratio "
                      f"{sc['pack_over_sg_us_per_byte_few_large']}, "
                      f"decisions few-large={sc['decision_few_large']} "
                      f"many-small={sc['decision_many_small']})")
            if name == "adaptive_drift":
                doc = adaptive_drift.merge_bench_json(rows)
                ad = doc["adaptive_drift"]
                print(f"# merged adaptive_drift rows into BENCH_transfer.json "
                      f"(post-drift static/online recovery ratio "
                      f"{ad['recovery_ratio_static_over_online']})")
            if name == "qos_contention":
                doc = qos_contention.merge_bench_json(rows)
                qc = doc["qos_contention"]
                print(f"# merged qos_contention rows into BENCH_transfer.json "
                      f"(token-RX p99 per-engine/runtime ratio "
                      f"{qc['p99_ratio_per_engine_over_runtime']}, "
                      f"fifo/runtime "
                      f"{qc['p99_ratio_fifo_over_runtime']}, coalescing "
                      f"b32 {doc['coalescing']['speedup_b32']}x)")
            if name == "tenant_isolation":
                ti = tenant_isolation.merge_bench_json(rows)
                print(f"# merged tenant_isolation rows into "
                      f"BENCH_transfer.json (victim p99 vs noflood: wfq "
                      f"{ti['isolation_ratio_wfq']}x, single-tier "
                      f"{ti['isolation_ratio_single_tier']}x)")
        except Exception as e:  # noqa: BLE001 — a merge failure is a failure
            print(f"# {name} MERGE ERROR: {e}", file=sys.stderr)
            failures.append(name)
    if failures:
        # a sub-benchmark that died must fail the run (the CI smoke lane
        # gates on this exit code — silent skips made the lane vacuous)
        print(f"# FAILED benches: {','.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
